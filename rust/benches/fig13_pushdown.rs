//! Fig. 13 reproduction: predicate pushdown on the disaggregated-storage
//! setup — TPC-H SF10, 1% selectivity, scan cores 1 → max per DPU,
//! against the fetch-everything baseline (33 MTPS).
//!
//! The scan itself *really executes* through the AOT JAX/Pallas artifact
//! on the PJRT CPU client when `artifacts/` is present (the measured host
//! scan rate is printed alongside the modeled per-platform series).

use dpbento::platform::PlatformId;
use dpbento::tasks::pred_pushdown::{pushdown_mtps, scan_native, scan_pjrt, BASELINE_MTPS};
use dpbento::util::bench::BenchTable;

fn main() {
    let mut t = BenchTable::new(
        "Fig. 13 — predicate pushdown (SF10, sel 1%)",
        "Mtuples/s",
    )
    .columns(&["baseline", "bf2", "bf3", "octeon"]);
    for cores in [1u32, 2, 4, 8, 16, 24] {
        t.row(
            format!("{cores}c"),
            vec![
                Some(BASELINE_MTPS),
                (cores <= 8).then(|| pushdown_mtps(PlatformId::Bf2, cores)),
                (cores <= 16).then(|| pushdown_mtps(PlatformId::Bf3, cores)),
                Some(pushdown_mtps(PlatformId::OcteonTx2, cores)),
            ],
        );
    }
    t.finish("fig13_pushdown");

    // real scan execution through the PJRT artifact (if built)
    let gen = dpbento::db::Gen::new(13, 100);
    let li = gen.lineitem(10.0);
    let qty = li.col("l_quantity").as_f32().unwrap();
    let price = li.col("l_extendedprice").as_f32().unwrap();
    let disc = li.col("l_discount").as_f32().unwrap();
    let (lo, hi) = (25.0f32, 25.0 + 0.49);

    let native = scan_native(qty, price, disc, lo, hi);
    println!(
        "\nreal scan (native rust): {} rows in {:.3}s = {:.1} MTPS, {} qualified",
        native.rows,
        native.seconds,
        native.rows as f64 / native.seconds / 1e6,
        native.qualified
    );
    match dpbento::runtime::Runtime::load(dpbento::runtime::artifact::default_dir()) {
        Ok(rt) => {
            let m = scan_pjrt(&rt, qty, price, disc, lo, hi).expect("pjrt scan");
            println!(
                "real scan (PJRT/Pallas):  {} rows in {:.3}s = {:.1} MTPS, {} qualified",
                m.rows,
                m.seconds,
                m.rows as f64 / m.seconds / 1e6,
                m.qualified
            );
            assert_eq!(m.qualified, native.qualified, "PJRT and native scans agree");
        }
        Err(e) => println!("(PJRT artifacts not available: {e:#} — run `make artifacts`)"),
    }

    // Fig. 13 shape checks
    assert!((1.7..1.9).contains(&(pushdown_mtps(PlatformId::Bf3, 1) / BASELINE_MTPS)));
    assert!((11.0..13.0).contains(&(pushdown_mtps(PlatformId::Bf3, 16) / BASELINE_MTPS)));
    for p in [PlatformId::Bf2, PlatformId::OcteonTx2] {
        assert!(pushdown_mtps(p, 2) > BASELINE_MTPS, "{p} crosses baseline at 2 cores");
        let full = pushdown_mtps(p, p.spec().cores) / BASELINE_MTPS;
        assert!((4.2..4.8).contains(&full), "{p} ~4.5x with all cores");
    }
    println!("\nfig13 shape checks passed: 1.8x/12x BF-3, 4.5x BF-2/OCTEON over the 33 MTPS baseline");
}
