//! Fig. 10 reproduction: storage latency at QD=1, one thread — average
//! (foreground bars) and p99 (grey background bars) for 8 KB and 4 MB
//! accesses, via the closed-loop device simulation.

use dpbento::platform::memory::{AccessOp, Pattern};
use dpbento::platform::PlatformId;
use dpbento::storage::Device;
use dpbento::util::bench::BenchTable;

fn main() {
    for (size, label, fig) in [(8usize << 10, "8KB", "10a"), (4 << 20, "4MB", "10b")] {
        let mut t = BenchTable::new(
            format!("Fig. {fig} — storage latency, {label} @ QD1"),
            "µs (avg | p99)",
        )
        .columns(&["avg", "p99"]);
        for p in [
            PlatformId::HostEpyc,
            PlatformId::Bf2,
            PlatformId::Bf3,
            PlatformId::OcteonTx2,
        ] {
            for (op, pat) in [
                (AccessOp::Read, Pattern::Random),
                (AccessOp::Read, Pattern::Sequential),
                (AccessOp::Write, Pattern::Random),
            ] {
                let dev = Device::for_platform(p);
                let run = dev.simulate(op, pat, size, 1, 1, 3000, 10);
                let s = run.latency_summary_us();
                t.row_f(
                    format!("{p} {} {}", pat.name(), op.name()),
                    &[s.mean, s.p99],
                );
            }
        }
        t.finish(&format!("fig{fig}_latency_{label}"));
    }

    // §6.1 shape checks
    let bf3 = Device::for_platform(PlatformId::Bf3);
    let host = Device::for_platform(PlatformId::HostEpyc);
    let bf3_8k = bf3.simulate(AccessOp::Read, Pattern::Random, 8 << 10, 1, 1, 3000, 1)
        .latency_summary_us();
    let host_8k = host
        .simulate(AccessOp::Read, Pattern::Random, 8 << 10, 1, 1, 3000, 1)
        .latency_summary_us();
    assert!(bf3_8k.mean < host_8k.mean, "BF-3 8 KB avg latency below host");
    assert!(bf3_8k.p99 < host_8k.p99, "BF-3 8 KB p99 ~20% below host");
    let bf3_4m = bf3.service_mean_s(AccessOp::Read, 4 << 20);
    let host_4m = host.service_mean_s(AccessOp::Read, 4 << 20);
    assert!((3.0..5.0).contains(&(bf3_4m / host_4m)), "3-5x at 4 MB");
    println!("\nfig10 shape checks passed: BF-3 wins fine-grained latency, loses bandwidth-bound 4 MB");
}
