//! Ablation sweeps over the benchmark parameters the paper's figures hold
//! fixed (DESIGN.md §5 calls these out): storage queue depth, network
//! queue depth, pushdown selectivity, and the index split ratio. These
//! verify the *models'* sensitivity behaves physically — saturation
//! curves, diminishing returns — not just the calibrated anchor points.

use dpbento::index::partition::{index_rate_mops, offloaded_throughput_mops};
use dpbento::net::tcp;
use dpbento::platform::memory::{AccessOp, Pattern};
use dpbento::platform::PlatformId;
use dpbento::storage::Device;
use dpbento::tasks::pred_pushdown::{pushdown_mtps, BASELINE_MTPS};
use dpbento::util::bench::BenchTable;

fn main() {
    // --- storage queue depth (Fig. 9 holds depth at the tuned optimum)
    let mut t = BenchTable::new("Ablation — storage 8 KB random-read vs queue depth", "MB/s")
        .columns(&["host", "bf3", "bf2"]);
    let mut prev = [0.0f64; 3];
    for depth in [1u32, 2, 4, 8, 16, 32, 64, 128, 256] {
        let row: Vec<f64> = [PlatformId::HostEpyc, PlatformId::Bf3, PlatformId::Bf2]
            .iter()
            .map(|&p| Device::for_platform(p).throughput_mbps(AccessOp::Read, Pattern::Random, 8192, depth, 1))
            .collect();
        // monotone non-decreasing in depth
        for (i, (&now, &before)) in row.iter().zip(prev.iter()).enumerate() {
            assert!(now + 1e-9 >= before, "col {i} depth {depth}");
        }
        prev = [row[0], row[1], row[2]];
        t.row_f(format!("qd{depth}"), &row);
    }
    t.finish("ablation_storage_depth");
    // saturation: host stops gaining once its 32 channels are covered
    let h = Device::for_platform(PlatformId::HostEpyc);
    assert_eq!(
        h.throughput_mbps(AccessOp::Read, Pattern::Random, 8192, 64, 1),
        h.throughput_mbps(AccessOp::Read, Pattern::Random, 8192, 256, 1)
    );

    // --- network queue depth (Fig. 11b holds QD=128)
    let mut t = BenchTable::new("Ablation — TCP 32 KB single-conn vs queue depth", "Gbps")
        .columns(&["dpu", "host"]);
    for depth in [1u32, 2, 4, 8, 16, 64, 128] {
        t.row_f(
            format!("qd{depth}"),
            &[
                tcp::throughput_gbps(PlatformId::Bf2, 32 << 10, 1, depth),
                tcp::throughput_gbps(PlatformId::HostEpyc, 32 << 10, 1, depth),
            ],
        );
    }
    t.finish("ablation_tcp_depth");
    // shallow pipes cannot saturate, deep ones plateau
    assert!(
        tcp::throughput_gbps(PlatformId::HostEpyc, 32 << 10, 1, 1)
            < tcp::throughput_gbps(PlatformId::HostEpyc, 32 << 10, 1, 128)
    );

    // --- pushdown: the DPU-side win is selectivity-independent in the
    // model (scan-rate-bound), but the *baseline* alternative of shipping
    // qualified tuples only would scale with selectivity — report the
    // bytes-returned ratio that makes pushdown attractive.
    let mut t = BenchTable::new(
        "Ablation — pushdown data-reduction vs selectivity (SF10)",
        "ratio / MTPS",
    )
    .columns(&["bytes_returned_pct", "bf3_speedup"]);
    for sel in [0.001f64, 0.01, 0.1, 0.5, 1.0] {
        t.row_f(
            format!("sel={sel}"),
            &[
                100.0 * sel,
                pushdown_mtps(PlatformId::Bf3, 16) / BASELINE_MTPS,
            ],
        );
    }
    t.finish("ablation_pushdown_selectivity");

    // --- index split ratio (Fig. 14 holds 10:1): the DPU-side share of
    // the keyspace does not change the additive throughput model, but it
    // bounds how much of the *capacity* the DPU partition can absorb
    // before its service rate becomes the constraint.
    let mut t = BenchTable::new("Ablation — index gain vs DPU threads", "Mops/s")
        .columns(&["bf2", "bf3", "octeon"]);
    for threads in [1u32, 2, 4, 8, 16, 24] {
        t.row_f(
            format!("{threads}t"),
            &[
                offloaded_throughput_mops(PlatformId::Bf2, 96, threads),
                offloaded_throughput_mops(PlatformId::Bf3, 96, threads),
                offloaded_throughput_mops(PlatformId::OcteonTx2, 96, threads),
            ],
        );
    }
    t.finish("ablation_index_threads");
    // never below the host-only baseline; monotone in threads
    let base = index_rate_mops(PlatformId::HostEpyc, 96);
    for p in PlatformId::DPUS {
        assert!(offloaded_throughput_mops(p, 96, 1) >= base);
        assert!(
            offloaded_throughput_mops(p, 96, 8) >= offloaded_throughput_mops(p, 96, 2)
        );
    }

    println!("\nablation checks passed: saturation and monotonicity behave physically");
}
