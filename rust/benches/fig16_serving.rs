//! Fig. 16 (beyond the paper): the serving regime — throughput–latency
//! curves for the offload service under every registered scheduler, on
//! each host+DPU deployment, plus the batching/goodput extension.
//!
//! The batch benchmarks (Figs. 4–15) ask "how fast is one offloaded
//! run?"; this bench asks the production question: at what offered load
//! does each deployment stop meeting its SLO, and how much host CPU does
//! offloading free before that happens?

use dpbento::fault::FaultSpec;
use dpbento::obs::Obs;
use dpbento::platform::PlatformId;
use dpbento::serve::{
    capacity_rps, host_only_capacity_rps, run_sweep, scheduler, Mix, ServeConfig, SweepSpec,
};
use dpbento::util::bench::BenchTable;

const SEED: u64 = 16;
const REQUESTS: usize = 4000;
const LOADS: [f64; 5] = [0.2, 0.5, 0.8, 1.0, 1.2];

fn load_spec(cfg: &ServeConfig) -> SweepSpec {
    let host_cap = host_only_capacity_rps(cfg);
    let rates: Vec<f64> = LOADS.iter().map(|l| l * host_cap).collect();
    SweepSpec::open(&rates)
}

fn run_sched(
    dpu: PlatformId,
    sched: &'static str,
    mix: &Mix,
    max_batch: usize,
) -> Vec<dpbento::serve::LoadPoint> {
    let mut cfg = ServeConfig::new(Some(dpu), sched, mix.clone(), SEED);
    cfg.total_requests = REQUESTS;
    cfg.max_batch = max_batch;
    run_sweep(&cfg, &load_spec(&cfg), &Obs::disabled())
}

fn main() {
    let mix = Mix::from_name("mixed").expect("mixed workload");
    let names: Vec<&'static str> = scheduler::REGISTRY.iter().map(|i| i.name).collect();

    for dpu in [PlatformId::Bf2, PlatformId::Bf3] {
        let mut tput = BenchTable::new(
            format!("Fig. 16a — achieved throughput, host+{dpu} (mixed workload)"),
            "req/s",
        )
        .columns(&names);
        let mut p99 = BenchTable::new(
            format!("Fig. 16b — p99 latency, host+{dpu} (mixed workload)"),
            "µs",
        )
        .columns(&names);
        let mut freed = BenchTable::new(
            format!("Fig. 16c — host CPU per request, host+{dpu}"),
            "µs/req",
        )
        .columns(&names);
        let mut goodput = BenchTable::new(
            format!("Fig. 16d — SLO-constrained goodput, host+{dpu} (max_batch 8)"),
            "req/s",
        )
        .columns(&names);

        let curves: Vec<Vec<dpbento::serve::LoadPoint>> = names
            .iter()
            .map(|&s| run_sched(dpu, s, &mix, 1))
            .collect();
        let batched: Vec<Vec<dpbento::serve::LoadPoint>> = names
            .iter()
            .map(|&s| run_sched(dpu, s, &mix, 8))
            .collect();
        for (li, load) in LOADS.iter().enumerate() {
            let label = format!("{:.0}% host cap", load * 100.0);
            tput.row_f(
                label.clone(),
                &curves.iter().map(|c| c[li].achieved_rps).collect::<Vec<_>>(),
            );
            p99.row_f(
                label.clone(),
                &curves.iter().map(|c| c[li].p99_us).collect::<Vec<_>>(),
            );
            freed.row_f(
                label.clone(),
                &curves
                    .iter()
                    .map(|c| c[li].host_cpu_us_per_req)
                    .collect::<Vec<_>>(),
            );
            goodput.row_f(
                label,
                &batched.iter().map(|c| c[li].goodput_rps).collect::<Vec<_>>(),
            );
        }
        tput.finish(&format!("fig16a_serving_tput_{dpu}"));
        p99.finish(&format!("fig16b_serving_p99_{dpu}"));
        freed.finish(&format!("fig16c_serving_hostcpu_{dpu}"));
        goodput.finish(&format!("fig16d_serving_goodput_{dpu}"));

        // chaos panel (DESIGN.md §11): the same deployment with every DPU
        // core fail-stopped 10ms in — resilience-first routing vs a blind
        // split, by goodput and availability
        let chaos_scheds = ["static-split", "failover"];
        let mut chaos_good = BenchTable::new(
            format!("Fig. 16e — goodput under DPU fail-stop, host+{dpu} (canned chaos)"),
            "req/s",
        )
        .columns(&chaos_scheds);
        let mut chaos_avail = BenchTable::new(
            format!("Fig. 16f — availability under DPU fail-stop, host+{dpu}"),
            "frac",
        )
        .columns(&chaos_scheds);
        let faults = FaultSpec::canned_dpu_failstop();
        let chaos: Vec<Vec<dpbento::serve::LoadPoint>> = chaos_scheds
            .iter()
            .map(|&s| {
                let mut cfg = ServeConfig::new(Some(dpu), s, mix.clone(), SEED);
                cfg.total_requests = REQUESTS;
                cfg.retry.timeout_us = 50_000.0;
                cfg.retry.budget = 3;
                let spec = load_spec(&cfg).with_faults(faults.clone());
                run_sweep(&cfg, &spec, &Obs::disabled())
            })
            .collect();
        for (li, load) in LOADS.iter().enumerate() {
            let label = format!("{:.0}% host cap", load * 100.0);
            chaos_good.row_f(
                label.clone(),
                &chaos.iter().map(|c| c[li].goodput_rps).collect::<Vec<_>>(),
            );
            chaos_avail.row_f(
                label,
                &chaos.iter().map(|c| c[li].availability).collect::<Vec<_>>(),
            );
        }
        chaos_good.finish(&format!("fig16e_serving_chaos_goodput_{dpu}"));
        chaos_avail.finish(&format!("fig16f_serving_chaos_avail_{dpu}"));
        let mid = 1; // 50% host cap: the host survivor can absorb the load
        assert!(
            chaos[1][mid].goodput_rps > chaos[0][mid].goodput_rps,
            "failover must out-serve static-split with the DPU dead"
        );
        assert!(
            chaos[1][mid].availability > chaos[0][mid].availability,
            "failover must keep more requests alive with the DPU dead"
        );

        // deadline panel: the same deployment drained fifo vs edf at
        // fractions of the *full* deployment capacity — past the knee a
        // backlog forms and EDF reorders it toward urgent work, so
        // SLO-constrained goodput holds up and the tightest class
        // misses fewer deadlines
        let queues = ["fifo", "edf"];
        let mut dl_good = BenchTable::new(
            format!("Fig. 16g — goodput by queue discipline, host+{dpu} (slo-aware, max_batch 8)"),
            "req/s",
        )
        .columns(&queues);
        let mut dl_miss = BenchTable::new(
            format!("Fig. 16h — deadline-miss rate by queue discipline, host+{dpu}"),
            "frac",
        )
        .columns(&queues);
        let knee_loads = [0.8, 1.0, 1.25];
        let dl: Vec<Vec<dpbento::serve::LoadPoint>> = queues
            .iter()
            .map(|&q| {
                let mut cfg = ServeConfig::new(Some(dpu), "slo-aware", mix.clone(), SEED);
                cfg.total_requests = REQUESTS;
                cfg.max_batch = 8;
                cfg.queue = q;
                let cap = capacity_rps(&cfg);
                let rates: Vec<f64> = knee_loads.iter().map(|l| l * cap).collect();
                run_sweep(&cfg, &SweepSpec::open(&rates), &Obs::disabled())
            })
            .collect();
        for (li, load) in knee_loads.iter().enumerate() {
            let label = format!("{:.0}% capacity", load * 100.0);
            dl_good.row_f(
                label.clone(),
                &dl.iter().map(|c| c[li].goodput_rps).collect::<Vec<_>>(),
            );
            dl_miss.row_f(
                label,
                &dl.iter()
                    .map(|c| c[li].deadline_miss_rate())
                    .collect::<Vec<_>>(),
            );
        }
        dl_good.finish(&format!("fig16g_serving_queue_goodput_{dpu}"));
        dl_miss.finish(&format!("fig16h_serving_queue_dlmiss_{dpu}"));
        let over = knee_loads.len() - 1; // 125% of the analytic knee
        assert!(
            dl[1][over].goodput_rps >= dl[0][over].goodput_rps,
            "edf must not lose goodput to fifo past the knee ({} vs {})",
            dl[1][over].goodput_rps,
            dl[0][over].goodput_rps
        );
        assert!(
            dl[1][over].deadline_miss_rate() <= dl[0][over].deadline_miss_rate(),
            "edf must not miss more deadlines than fifo past the knee"
        );

        // shape checks mirroring the serving integration tests
        let host_only = &curves[0];
        let dpu_only = &curves[1];
        let qa = &curves[3];
        let high = LOADS.len() - 1;
        assert!(
            dpu_only[high].achieved_rps < host_only[high].achieved_rps,
            "dpu-only must saturate first"
        );
        assert!(
            qa[high].achieved_rps >= host_only[high].achieved_rps * 0.95,
            "queue-aware must keep up with host-only at high load"
        );
        println!(
            "\n{dpu}: dpu-only knee {:.0}/s, host-only knee {:.0}/s, queue-aware knee {:.0}/s",
            run_capacity(dpu, "dpu-only", &mix),
            run_capacity(dpu, "host-only", &mix),
            run_capacity(dpu, "queue-aware", &mix),
        );
    }
    println!("\nfig16 shape checks passed: wimpy-core pools saturate early; dynamic placement holds the SLO");
}

fn run_capacity(dpu: PlatformId, sched: &'static str, mix: &Mix) -> f64 {
    capacity_rps(&ServeConfig::new(Some(dpu), sched, mix.clone(), SEED))
}
