//! Fig. 16 (beyond the paper): the serving regime — throughput–latency
//! curves for the offload service under every placement policy, on each
//! host+DPU deployment.
//!
//! The batch benchmarks (Figs. 4–15) ask "how fast is one offloaded
//! run?"; this bench asks the production question: at what offered load
//! does each deployment stop meeting its SLO, and how much host CPU does
//! offloading free before that happens?

use dpbento::platform::PlatformId;
use dpbento::serve::{capacity_rps, host_only_capacity_rps, sweep, Mix, Policy, ServeConfig};
use dpbento::util::bench::BenchTable;

const SEED: u64 = 16;
const REQUESTS: usize = 4000;
const LOADS: [f64; 5] = [0.2, 0.5, 0.8, 1.0, 1.2];

fn run_policy(dpu: PlatformId, policy: Policy, mix: &Mix) -> Vec<dpbento::serve::LoadPoint> {
    let mut cfg = ServeConfig::new(Some(dpu), policy, mix.clone(), SEED);
    cfg.total_requests = REQUESTS;
    let host_cap = host_only_capacity_rps(&cfg);
    let rates: Vec<f64> = LOADS.iter().map(|l| l * host_cap).collect();
    sweep(&cfg, &rates)
}

fn main() {
    let mix = Mix::from_name("mixed").expect("mixed workload");

    for dpu in [PlatformId::Bf2, PlatformId::Bf3] {
        let mut tput = BenchTable::new(
            format!("Fig. 16a — achieved throughput, host+{dpu} (mixed workload)"),
            "req/s",
        )
        .columns(&["host-only", "dpu-only", "static-split", "queue-aware"]);
        let mut p99 = BenchTable::new(
            format!("Fig. 16b — p99 latency, host+{dpu} (mixed workload)"),
            "µs",
        )
        .columns(&["host-only", "dpu-only", "static-split", "queue-aware"]);
        let mut freed = BenchTable::new(
            format!("Fig. 16c — host CPU per request, host+{dpu}"),
            "µs/req",
        )
        .columns(&["host-only", "dpu-only", "static-split", "queue-aware"]);

        let curves: Vec<Vec<dpbento::serve::LoadPoint>> = Policy::ALL
            .iter()
            .map(|p| run_policy(dpu, *p, &mix))
            .collect();
        for (li, load) in LOADS.iter().enumerate() {
            let label = format!("{:.0}% host cap", load * 100.0);
            tput.row_f(
                label.clone(),
                &curves.iter().map(|c| c[li].achieved_rps).collect::<Vec<_>>(),
            );
            p99.row_f(
                label.clone(),
                &curves.iter().map(|c| c[li].p99_us).collect::<Vec<_>>(),
            );
            freed.row_f(
                label,
                &curves
                    .iter()
                    .map(|c| c[li].host_cpu_us_per_req)
                    .collect::<Vec<_>>(),
            );
        }
        tput.finish(&format!("fig16a_serving_tput_{dpu}"));
        p99.finish(&format!("fig16b_serving_p99_{dpu}"));
        freed.finish(&format!("fig16c_serving_hostcpu_{dpu}"));

        // shape checks mirroring the serving integration tests
        let dpu_only = &curves[1];
        let host_only = &curves[0];
        let qa = &curves[3];
        let high = LOADS.len() - 1;
        assert!(
            dpu_only[high].achieved_rps < host_only[high].achieved_rps,
            "dpu-only must saturate first"
        );
        assert!(
            qa[high].achieved_rps >= host_only[high].achieved_rps * 0.95,
            "queue-aware must keep up with host-only at high load"
        );
        println!(
            "\n{dpu}: dpu-only knee {:.0}/s, host-only knee {:.0}/s, queue-aware knee {:.0}/s",
            run_capacity(dpu, Policy::DpuOnly, &mix),
            run_capacity(dpu, Policy::HostOnly, &mix),
            run_capacity(dpu, Policy::QueueAware, &mix),
        );
    }
    println!("\nfig16 shape checks passed: wimpy-core pools saturate early; dynamic placement holds the SLO");
}

fn run_capacity(dpu: PlatformId, policy: Policy, mix: &Mix) -> f64 {
    capacity_rps(&ServeConfig::new(Some(dpu), policy, mix.clone(), SEED))
}
